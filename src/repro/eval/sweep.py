"""Accuracy-vs-bits sweep through the serving path (the quality bench).

    PYTHONPATH=src python -m repro.eval.sweep --steps 260 --engine packed

Trains the tiny offline LM, then measures MCQ accuracy and held-out
perplexity at fp and at INT{8,4,2} x {linear baseline, SplitQuantV2} —
every number produced by :mod:`repro.eval.serving` evaluators running
through the real ``BatchedServer`` engine path. Appends one
``quality``-kind record of ``quality/*`` rows to the persistent bench
trajectory (``BENCH_quant_engine.json``), so the accuracy trajectory
rides next to the perf trajectory and the CI quality gate can assert the
paper's Table-1 signature on the latest record.

``--quant-report PATH`` additionally writes the per-layer
:class:`repro.core.QuantReport` artifacts (one per swept bit width,
worst layer first) — the attribution companion to the task-level rows.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

from repro.core import QuantPolicy, build_quant_report, restructure
from repro.data.pipeline import SyntheticLM
from repro.eval.serving import serve_mcq_accuracy, serve_perplexity
from repro.eval.tasks import eval_sequences, mcq_problems
from repro.eval.train import DATA_SEED, train_small_lm

BENCH_PATH = pathlib.Path(__file__).resolve().parents[3] / (
    "BENCH_quant_engine.json"
)


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=260,
                    help="tiny-LM pretrain steps (the pinned recipe)")
    ap.add_argument("--engine", default="packed",
                    choices=("fake", "packed", "planes"),
                    help="quantized execution path under the server")
    ap.add_argument("--bits", default="8,4,2",
                    help="comma-separated bit widths to sweep")
    ap.add_argument("--mcq", type=int, default=200,
                    help="4-way MCQ problems per accuracy cell")
    ap.add_argument("--ppl-seqs", type=int, default=16,
                    help="held-out sequences per perplexity cell")
    ap.add_argument("--ppl-len", type=int, default=48,
                    help="tokens per perplexity sequence")
    ap.add_argument("--ppl-ctx", type=int, default=8,
                    help="context tokens given for free (not scored)")
    ap.add_argument("--slots", type=int, default=8,
                    help="server batch slots the evaluators run over")
    ap.add_argument("--out", default=str(BENCH_PATH),
                    help="bench trajectory JSON to append the record to")
    ap.add_argument("--quant-report", default="",
                    help="write per-layer QuantReport artifacts (one JSON "
                         "with an entry per bit width) to this path")
    return ap


def _quantize(params, bits: int, split: bool, engine: str):
    qm = restructure(params, QuantPolicy(bits=bits, split=split,
                                         packed=engine == "packed"))
    if engine == "fake":
        return qm.materialize()
    return qm.as_executable(group=True)


def run_sweep(args) -> tuple[list[tuple[str, float, str]], dict]:
    """Returns ``(rows, record)``: printable bench rows plus the JSON
    record appended to the trajectory."""
    t0 = time.time()
    bit_widths = [int(b) for b in args.bits.split(",") if b]
    cfg, model, params, loss = train_small_lm(steps=args.steps)
    problems = mcq_problems(cfg.vocab_size, args.mcq)
    seqs = eval_sequences(SyntheticLM(cfg.vocab_size, seed=DATA_SEED),
                          args.ppl_seqs, args.ppl_len)

    rows: list[tuple[str, float, str]] = [
        ("quality/train_loss", loss,
         f"tiny llama32-1b (reduced), {args.steps} steps"),
    ]
    acc: dict[str, float] = {}
    ppl: dict[str, float] = {}

    def cell(tag: str, p, note: str):
        a = serve_mcq_accuracy(model, p, problems, slots=args.slots)
        px = serve_perplexity(model, p, seqs, ctx_len=args.ppl_ctx,
                              slots=args.slots)
        acc[tag], ppl[tag] = a, px["ppl"]
        rows.append((f"quality/acc_{tag}", a, note))
        rows.append((f"quality/ppl_{tag}", px["ppl"], note))
        print(f"[sweep] {tag:16s} acc={a:.3f} ppl={px['ppl']:.3f} ({note})")

    cell("fp", params, "unquantized serving path")
    reports = {}
    for bits in bit_widths:
        cell(f"int{bits}_baseline",
             _quantize(params, bits, False, args.engine),
             f"linear INT{bits}, {args.engine} engine")
        cell(f"int{bits}_split",
             _quantize(params, bits, True, args.engine),
             f"SplitQuantV2 INT{bits}, {args.engine} engine")
        rep = build_quant_report(params, QuantPolicy(
            bits=bits, split=True, packed=args.engine == "packed"))
        reports[f"int{bits}"] = rep.to_json()
    if 4 in bit_widths:
        rows.append(("quality/int4_split_recovery",
                     acc["int4_split"] - acc["int4_baseline"],
                     "the paper's headline: SplitQuantV2's accuracy win "
                     "over the linear baseline at INT4"))
    rows.append(("quality/wall_s", time.time() - t0, "total sweep time"))

    record = {
        "ts": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "kind": "quality",
        "engine": args.engine,
        "train": {"steps": args.steps, "loss": loss},
        "tasks": {"mcq_problems": args.mcq, "ppl_seqs": args.ppl_seqs,
                  "ppl_len": args.ppl_len, "ppl_ctx": args.ppl_ctx},
        "accuracy": acc,
        "perplexity": ppl,
        "quant_summaries": {k: v["summary"] for k, v in reports.items()},
        "rows": [{"name": n, "value": v, "note": d} for n, v, d in rows],
    }
    if args.quant_report:
        with open(args.quant_report, "w") as f:
            json.dump(reports, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"[sweep] quant reports -> {args.quant_report}")
    return rows, record


def append_record(path: pathlib.Path, record: dict) -> int:
    """Append into the shared ``{"schema": 2, "runs": [...]}`` trajectory
    file (the same shape ``benchmarks/kernel_bench.py`` maintains)."""
    runs = []
    if path.exists():
        try:
            prev = json.loads(path.read_text())
            runs = prev.get("runs", [prev] if "serve" in prev else [])
        except (json.JSONDecodeError, OSError):
            runs = []
    runs.append(record)
    path.write_text(json.dumps({"schema": 2, "runs": runs}, indent=2))
    return len(runs)


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    rows, record = run_sweep(args)
    out = pathlib.Path(args.out)
    n = append_record(out, record)
    for r in rows:
        print(r)
    print(f"[sweep] {out.name}: {n} run(s) recorded")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
