"""Block-shape dispatch for the quantized matmul kernels.

Two layers, cheapest first:

1. **Heuristic defaults** keyed on (M, K, N, bits): MXU-aligned block
   shapes chosen per problem shape (decode M is tiny -> small bm; big K ->
   big bk to amortize grid overhead; bn capped by a VMEM budget for the
   fp32 accumulator + unpacked weight tile).
2. **Measured cache**: an optional JSON file (``SPLITQ_TUNE_CACHE`` env var
   or an explicit path) mapping ``"MxKxN@bits/dS"`` -> ``[bm, bn, bk]``.
   ``autotune()`` times the candidate blocks for a concrete call and records
   the winner, so serving picks measured shapes on the next run — levanter-
   style config plumbing: the cache is plain data, reviewable and shippable.

Keys carry the tensor-parallel shard count (``/dS``): a TP shard runs the
*per-shard* matmul (N/S output columns per device), and a block tuned for
the full weight is the wrong answer for the shard. M/K/N in the key are the
per-shard shape; entries in the old global-shape format (no ``/dS`` suffix)
are stale by construction and dropped at load time.

All outputs satisfy the kernel contracts: bm % 8 == 0 (fp32 sublane; 16 for
bf16 activations), bn % 128 == 0 (lane), bk % 128 == 0, and for grouped
launches bn divides the group's N alignment so every output block belongs
to exactly one member.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import re
from typing import Callable, Iterable

ENV_CACHE = "SPLITQ_TUNE_CACHE"

# VMEM working-set budget per kernel instance (acc fp32 + x tile + unpacked
# weight tile + double-buffered packed tiles). Conservative vs the ~16 MB
# physical VMEM so the pipeline has headroom for double buffering.
VMEM_BUDGET = 8 * 1024 * 1024

BN_CANDIDATES = (512, 256, 128)
BK_CANDIDATES = (512, 256, 128)


@dataclasses.dataclass(frozen=True)
class BlockConfig:
    bm: int
    bn: int
    bk: int

    def astuple(self) -> tuple[int, int, int]:
        return (self.bm, self.bn, self.bk)


def _round_up(v: int, mult: int) -> int:
    return -(-v // mult) * mult


def _vmem_bytes(bm: int, bn: int, bk: int, bits: int) -> int:
    acc = bm * bn * 4
    x_tile = bm * bk * 4
    w_unpacked = bk * bn * 4
    w_packed = 2 * (bk * bn * (bits + 2) // 8)  # double-buffered stream
    return acc + x_tile + w_unpacked + w_packed


def heuristic_block(
    m: int, k: int, n: int, bits: int, *, max_bn: int | None = None,
    bf16_acts: bool = False,
) -> tuple[int, int, int]:
    """MXU-aligned default block shape for a (M, K) x (K, N) int-b matmul."""
    sublane = 16 if bf16_acts else 8
    bm = 128 if m >= 128 else _round_up(max(m, 1), sublane)
    bn = next((c for c in BN_CANDIDATES if n >= c), 128)
    if max_bn is not None:
        bn = min(bn, max_bn)
    bk = next((c for c in BK_CANDIDATES if k >= 4 * c), 128)
    bk = min(bk, _round_up(max(k, 1), 128))
    while _vmem_bytes(bm, bn, bk, bits) > VMEM_BUDGET and bn > 128:
        bn //= 2
    while _vmem_bytes(bm, bn, bk, bits) > VMEM_BUDGET and bk > 128:
        bk //= 2
    return (bm, bn, bk)


def candidate_blocks(
    m: int, k: int, n: int, bits: int, *, max_bn: int | None = None,
    bf16_acts: bool = False,
) -> list[tuple[int, int, int]]:
    """Small, valid candidate set around the heuristic for measurement."""
    base = heuristic_block(m, k, n, bits, max_bn=max_bn, bf16_acts=bf16_acts)
    out = {base}
    bm = base[0]
    for bn in BN_CANDIDATES:
        if max_bn is not None and bn > max_bn:
            continue
        for bk in BK_CANDIDATES:
            if _vmem_bytes(bm, bn, bk, bits) <= VMEM_BUDGET:
                out.add((bm, bn, bk))
    return sorted(out)


# ---------------------------------------------------------------------------
# Measured cache
# ---------------------------------------------------------------------------


def cache_key(m: int, k: int, n: int, bits: int, bf16_acts: bool = False,
              n_shards: int = 1) -> str:
    # activation dtype changes both the sublane constraint and the measured
    # winner, so bf16 entries get their own namespace; n_shards is the TP
    # degree the (m, k, n) PER-SHARD shape was tuned under — a shard must
    # never reuse a block tuned for the global weight (and vice versa)
    return (f"{m}x{k}x{n}@{bits}" + ("+bf16" if bf16_acts else "")
            + f"/d{n_shards}")


_KEY_RE = re.compile(r"^\d+x\d+x\d+@\d+(\+bf16)?/d\d+$")


def _valid_block_entry(v) -> bool:
    """A cache entry must be a 3-int [bm, bn, bk] list."""
    return (
        isinstance(v, (list, tuple)) and len(v) == 3
        and all(isinstance(x, int) and not isinstance(x, bool) for x in v)
    )


class TuneCache:
    """JSON-backed (M, K, N, bits) -> block mapping."""

    def __init__(self, path: str | os.PathLike | None = None):
        self.path = pathlib.Path(path) if path else None
        self.table: dict[str, tuple[int, int, int]] = {}
        if self.path and self.path.exists():
            try:
                raw = json.loads(self.path.read_text())
                # validate per entry at LOAD time: a hand-edited 2-element
                # (or non-int) entry must degrade to the heuristic here,
                # not raise inside choose_block on the serving hot path.
                # Keys missing the /dS shard suffix are schema-1 entries
                # tuned on GLOBAL shapes — stale for any sharded run and
                # ambiguous for unsharded ones, so they are dropped too.
                self.table = {k: tuple(v)
                              for k, v in raw.get("blocks", raw).items()
                              if _valid_block_entry(v)
                              and isinstance(k, str) and _KEY_RE.match(k)}
            except (json.JSONDecodeError, OSError, AttributeError, TypeError):
                # corrupt/truncated cache must not take down the hot path —
                # heuristics cover every shape
                self.table = {}

    def get(self, m: int, k: int, n: int, bits: int, bf16_acts: bool = False,
            n_shards: int = 1):
        return self.table.get(cache_key(m, k, n, bits, bf16_acts, n_shards))

    def put(self, m: int, k: int, n: int, bits: int,
            block: tuple[int, int, int], bf16_acts: bool = False,
            n_shards: int = 1):
        self.table[cache_key(m, k, n, bits, bf16_acts, n_shards)] = tuple(block)

    def save(self, path: str | os.PathLike | None = None):
        p = pathlib.Path(path) if path else self.path
        if p is None:
            raise ValueError("no cache path configured")
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(
            {"schema": 2, "blocks": {k: list(v) for k, v in
                                     sorted(self.table.items())}},
            indent=2,
        ))


_cache: TuneCache | None = None


def get_cache() -> TuneCache:
    global _cache
    if _cache is None:
        _cache = TuneCache(os.environ.get(ENV_CACHE) or None)
    return _cache


def reset_cache():
    global _cache
    _cache = None


def choose_block(
    m: int, k: int, n: int, bits: int, *, max_bn: int | None = None,
    bf16_acts: bool = False, n_shards: int = 1,
) -> tuple[int, int, int]:
    """Dispatch: measured cache hit if valid for this call, else heuristic.

    ``(m, k, n)`` is the PER-SHARD shape when ``n_shards > 1`` — callers
    running under tensor parallelism divide their output width first."""
    hit = get_cache().get(m, k, n, bits, bf16_acts, n_shards)
    if hit is not None and _valid_block_entry(hit):
        bm, bn, bk = hit
        sublane = 16 if bf16_acts else 8
        ok = bm % sublane == 0 and bn % 128 == 0 and bk % 128 == 0
        # re-check the VMEM budget on every hit: an entry tuned on another
        # machine (or hand-edited) may exceed this build's working set
        ok = ok and _vmem_bytes(bm, bn, bk, bits) <= VMEM_BUDGET
        if max_bn is not None:
            ok = ok and bn <= max_bn and max_bn % bn == 0
        if ok:
            _count("tune_cache_hits_total",
                   "choose_block served from the measured cache")
            return (bm, bn, bk)
    _count("tune_cache_misses_total",
           "choose_block fell back to the heuristic")
    return heuristic_block(m, k, n, bits, max_bn=max_bn, bf16_acts=bf16_acts)


def _count(name: str, help: str) -> None:
    """Bump a counter in the process-global obs registry. choose_block has
    no server handle in scope (it runs inside kernel dispatch), so tuning
    visibility rides the global registry, which every exporter merges."""
    from repro.obs.metrics import global_registry

    global_registry().counter(name, help).inc()


def autotune(
    run: Callable[[tuple[int, int, int]], object],
    m: int, k: int, n: int, bits: int,
    *, candidates: Iterable[tuple[int, int, int]] | None = None,
    iters: int = 3, max_bn: int | None = None, bf16_acts: bool = False,
    n_shards: int = 1,
) -> tuple[tuple[int, int, int], dict[str, float]]:
    """Time ``run(block)`` over the candidate set; record the winner.

    Timing goes through ``repro.obs.profile.timeit`` — the repo's one
    benchmark clock (warmup excludes compile, every iteration blocks on
    its own output, MEDIAN of ``iters`` so one GC pause can't crown the
    wrong block). Returns (best_block, {block_str: seconds}).
    """
    from repro.obs.profile import timeit

    cands = list(candidates or candidate_blocks(
        m, k, n, bits, max_bn=max_bn, bf16_acts=bf16_acts))
    timings: dict[str, float] = {}
    best, best_t = None, float("inf")
    last_err: Exception | None = None
    for block in cands:
        try:
            dt = timeit(run, block, iters=iters, warmup=1)
        except Exception as e:  # invalid block for this backend/shape
            last_err = e
            continue
        _count("autotune_trials_total", "candidate blocks measured")
        timings["x".join(map(str, block))] = dt
        if dt < best_t:
            best, best_t = block, dt
    if best is None:
        # EVERY candidate failed: that is a kernel/shape problem, not a
        # tuning outcome — don't record an untimed "winner" silently.
        raise RuntimeError(
            f"autotune: all {len(cands)} candidate blocks failed for "
            f"{cache_key(m, k, n, bits, bf16_acts, n_shards)}"
        ) from last_err
    get_cache().put(m, k, n, bits, best, bf16_acts, n_shards)
    _count("autotune_winners_total", "measured winners recorded")
    return best, timings
