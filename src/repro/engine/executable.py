"""Build an *executable* params pytree from a QuantizedModel.

``materialize()`` (core/apply.py) rebuilds dense fp32 weights — fake-quant
semantics, full-bandwidth serving. ``build_executable()`` instead returns a
params-like pytree whose hot-path matmul leaves stay in their quantized
storage containers (PackedSplitQTensor / SplitQTensor / QTensor); the model
forward routes those through the packed Pallas kernels via
``repro.engine.qmm.qdot``, so decode streams 6 bits/weight instead of 32.

Leaves the kernel path does not cover (MoE expert stacks, SSM mixers — the
grouped-expert kernel is a ROADMAP follow-on) are dequantized ONCE here,
which is bit-identical to ``materialize()`` for those leaves, keeping every
model family runnable from one executable tree.

``group=True`` additionally fuses sibling projections at restructure time:
``attn/{wq,wk,wv}`` -> ``attn/wqkv`` and ``mlp/{w_gate,w_up}`` ->
``mlp/w_gateup`` (packed codes concatenated along N, per-member LUTs kept —
bit-exact, see core.split.group_packed). A decode block then costs 4
quantized launches (qkv, wo, gate+up, w_down) instead of 7, and the qkv /
gate+up activations are read once instead of 3x / 2x.
"""
from __future__ import annotations

from typing import Any

import jax

from repro.core.split import PackedSplitQTensor, group_packed

# dict-key context in which a leaf name is executable by the kernel path
ATTN_KEYS = ("wq", "wk", "wv", "wo")
MLP_KEYS = ("w_up", "w_gate", "w_down")


def supports_kernel_path(path: str) -> bool:
    """True if the model forward routes this leaf through qdot()."""
    parts = path.split("/")
    if parts[-2:] == ["lm_head", "w"]:
        return True
    if len(parts) < 2:
        return False
    parent, name = parts[-2], parts[-1]
    if parent in ("attn", "cross_attn") and name in ATTN_KEYS:
        return True
    if parent in ("mlp", "shared") and name in MLP_KEYS:
        return True
    return False


def _dequantize_leaf(qm, path: str):
    qt = qm.qleaves[path]
    if qm.stacked[path]:
        return jax.vmap(lambda t: t.dequantize())(qt)
    return qt.dequantize()


def _group_dicts(node: Any, path: tuple[str, ...] = ()) -> Any:
    """Recursively fuse wq/wk/wv -> wqkv and w_gate/w_up -> w_gateup.

    Cross-attention and encoder self-attention are NOT grouped: their
    forwards need only a subset of (q, k, v) per call (q at decode, k/v at
    prefill/encode), and a fused launch cannot skip unused members — it
    would *double* weight reads exactly where grouping is meant to halve
    them. Decoder self-attention always needs all three, so it groups."""
    if not isinstance(node, dict):
        return node
    node = {k: _group_dicts(v, path + (k,)) for k, v in node.items()}
    partial_use = "enc" in path or (path and path[-1] == "cross_attn")
    qkv = [node.get(n) for n in ("wq", "wk", "wv")]
    if not partial_use and all(isinstance(t, PackedSplitQTensor) for t in qkv):
        rest = {k: v for k, v in node.items() if k not in ("wq", "wk", "wv")}
        rest["wqkv"] = group_packed(qkv)
        node = rest
    gu = [node.get(n) for n in ("w_gate", "w_up")]
    if all(isinstance(t, PackedSplitQTensor) for t in gu):
        rest = {k: v for k, v in node.items() if k not in ("w_gate", "w_up")}
        rest["w_gateup"] = group_packed(gu)
        node = rest
    return node


def build_executable(qm, *, group: bool = True) -> Any:
    """QuantizedModel -> executable params pytree.

    The result plugs into the unchanged Model API: ``model.decode_step(
    executable, tokens, cache)`` runs the packed kernels end-to-end.
    """
    leaves = []
    for p in qm.paths:
        if p in qm.qleaves:
            qt = qm.qleaves[p]
            if supports_kernel_path(p) and len(qt.shape) == 2:
                leaves.append(qt)
            else:
                leaves.append(_dequantize_leaf(qm, p))
        else:
            leaves.append(qm.passthrough[p])
    tree = jax.tree_util.tree_unflatten(qm.treedef, leaves)
    if group:
        tree = _group_dicts(tree)
    return tree


def weight_bytes(tree: Any) -> int:
    """Total bytes of every array in a params/executable tree."""
    import numpy as np

    tot = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        tot += int(np.prod(leaf.shape)) * leaf.dtype.itemsize
    return tot


def decode_weight_bytes(tree: Any, *, tie_embeddings: bool = True) -> int:
    """Bytes the DECODE step streams per token on a single chip.

    Excludes weights a decode step does not read in full: the encoder stack
    and cross-attention projections (read once per request at prefill), and
    the embedding table when untied (decode gathers one row; a TIED table is
    read in full by the logits matmul, so it stays counted)."""
    if not isinstance(tree, dict):
        return weight_bytes(tree)
    tot = 0
    for k, v in tree.items():
        if k in ("enc", "cross_attn"):
            continue
        if k == "embed" and not tie_embeddings:
            continue
        if isinstance(v, dict):
            tot += decode_weight_bytes(v, tie_embeddings=tie_embeddings)
        else:
            tot += weight_bytes(v)
    return tot
