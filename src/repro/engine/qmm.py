"""Quantized matmul routing for model forwards.

``qdot(x, w)`` is the single entry point the model code calls wherever it
used to write ``x @ w``: dense arrays go through ``jnp.dot`` unchanged;
quantized containers (produced by ``QuantizedModel.as_executable()``) are
routed to the matching packed Pallas kernel with an autotuned block shape.
The grouped helpers understand the fused-projection containers
(``wqkv`` / ``w_gateup``) that ``as_executable(group=True)`` installs, so
decode runs 3-launch attention (qkv, out) + 2-launch MLP instead of 7
separate quantized matmuls per transformer block.

Under exact-TP serving hints (``sharding_hints(mesh, exact_tp=True)``)
every qdot wraps its input and output in an ``act_constraint("matmul_io")``:
activations replicate over ``model`` while the weight (dense or packed
planes) stays output-dim-sharded, so the only collective GSPMD can insert
is a value-exact all-gather of the product — never a partial-sum
all-reduce — keeping greedy streams bit-identical to the unsharded path.
Outside the hints context the constraints are no-ops.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantize import QTensor
from repro.core.split import PackedSplitQGroup, PackedSplitQTensor, SplitQTensor
from repro.kernels import ops
from repro.runtime.sharding import act_constraint


def qdot(x: jax.Array, w) -> jax.Array:
    """x @ Ŵ for a dense array or any quantized container."""
    x = act_constraint(x, "matmul_io")
    if isinstance(w, PackedSplitQTensor):
        y = ops.splitq_packed_matmul(x, w)
    elif isinstance(w, SplitQTensor):
        y = ops.splitq_matmul(x, w)
    elif isinstance(w, QTensor):
        y = ops.quant_matmul(x, w.packed, w.qp.scale, w.qp.zero, w.qp.bits)
    elif isinstance(w, PackedSplitQGroup):
        raise TypeError("grouped weights need qdot_group / the *_proj helpers")
    else:
        y = x @ w
    return act_constraint(y, "matmul_io")


def qdot_group(x: jax.Array, grp: PackedSplitQGroup) -> list[jax.Array]:
    """One fused kernel launch; per-member outputs."""
    x = act_constraint(x, "matmul_io")
    return [act_constraint(y, "matmul_io")
            for y in ops.splitq_packed_group_matmul(x, grp)]


# ---------------------------------------------------------------------------
# Projection helpers — model code stays agnostic of grouping.
# ---------------------------------------------------------------------------


def qkv_proj(p: dict, x: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """(q, k, v) 2-D projections; ONE kernel launch when grouped."""
    if "wqkv" in p:
        q, k, v = qdot_group(x, p["wqkv"])
        return q, k, v
    return qdot(x, p["wq"]), qdot(x, p["wk"]), qdot(x, p["wv"])


def q_proj(p: dict, x: jax.Array) -> jax.Array:
    """Query projection only (cross-attention decode)."""
    if "wqkv" in p:
        return qdot_group(x, p["wqkv"])[0]
    return qdot(x, p["wq"])


def kv_proj(p: dict, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Key/value projections only (encoder self-attn, cross-KV build)."""
    if "wqkv" in p:
        _, k, v = qdot_group(x, p["wqkv"])
        return k, v
    return qdot(x, p["wk"]), qdot(x, p["wv"])


def gate_up_proj(p: dict, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(gate, up) for a GLU MLP; ONE kernel launch when grouped."""
    if "w_gateup" in p:
        gate, up = qdot_group(x, p["w_gateup"])
        return gate, up
    return qdot(x, p["w_gate"]), qdot(x, p["w_up"])
