"""Quantized matmul routing for model forwards.

``qdot(x, w)`` is the single entry point the model code calls wherever it
used to write ``x @ w``: dense arrays go through ``jnp.dot`` unchanged;
quantized containers (produced by ``QuantizedModel.as_executable()``) are
routed to the matching packed Pallas kernel with an autotuned block shape.
The grouped helpers understand the fused-projection containers
(``wqkv`` / ``w_gateup``) that ``as_executable(group=True)`` installs, so
decode runs 3-launch attention (qkv, out) + 2-launch MLP instead of 7
separate quantized matmuls per transformer block.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantize import QTensor
from repro.core.split import PackedSplitQGroup, PackedSplitQTensor, SplitQTensor
from repro.kernels import ops


def qdot(x: jax.Array, w) -> jax.Array:
    """x @ Ŵ for a dense array or any quantized container."""
    if isinstance(w, PackedSplitQTensor):
        return ops.splitq_packed_matmul(x, w)
    if isinstance(w, SplitQTensor):
        return ops.splitq_matmul(x, w)
    if isinstance(w, QTensor):
        return ops.quant_matmul(x, w.packed, w.qp.scale, w.qp.zero, w.qp.bits)
    if isinstance(w, PackedSplitQGroup):
        raise TypeError("grouped weights need qdot_group / the *_proj helpers")
    return x @ w


def qdot_group(x: jax.Array, grp: PackedSplitQGroup) -> list[jax.Array]:
    """One fused kernel launch; per-member outputs."""
    return ops.splitq_packed_group_matmul(x, grp)


# ---------------------------------------------------------------------------
# Projection helpers — model code stays agnostic of grouping.
# ---------------------------------------------------------------------------


def qkv_proj(p: dict, x: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """(q, k, v) 2-D projections; ONE kernel launch when grouped."""
    if "wqkv" in p:
        q, k, v = qdot_group(x, p["wqkv"])
        return q, k, v
    return qdot(x, p["wq"]), qdot(x, p["wk"]), qdot(x, p["wv"])


def q_proj(p: dict, x: jax.Array) -> jax.Array:
    """Query projection only (cross-attention decode)."""
    if "wqkv" in p:
        return qdot_group(x, p["wqkv"])[0]
    return qdot(x, p["wq"])


def kv_proj(p: dict, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Key/value projections only (encoder self-attn, cross-KV build)."""
    if "wqkv" in p:
        _, k, v = qdot_group(x, p["wqkv"])
        return k, v
    return qdot(x, p["wk"]), qdot(x, p["wv"])


def gate_up_proj(p: dict, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(gate, up) for a GLU MLP; ONE kernel launch when grouped."""
    if "w_gateup" in p:
        gate, up = qdot_group(x, p["w_gateup"])
        return gate, up
    return qdot(x, p["w_gate"]), qdot(x, p["w_up"])
