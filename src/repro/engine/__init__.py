"""Quantized execution engine: packed-kernel serving of SplitQuantV2 models.

The seed stored quantized weights but served fake-quant (dense fp32). This
package makes quantized weights *executable*:

* :mod:`repro.engine.executable` — ``QuantizedModel.as_executable()`` trees
  whose hot-path leaves are packed containers, plus fused QKV / gate+up
  projection grouping.
* :mod:`repro.engine.qmm` — ``qdot`` routing (dense vs packed kernels) used
  by the model forwards.
* :mod:`repro.engine.autotune` — block-shape dispatch: MXU-aligned
  heuristics keyed on (M, K, N, bits) plus an optional measured JSON cache.
"""
from repro.engine import autotune
from repro.engine.autotune import (
    choose_block,
    get_cache,
    heuristic_block,
    TuneCache,
)
from repro.engine.executable import (
    build_executable,
    decode_weight_bytes,
    supports_kernel_path,
    weight_bytes,
)
from repro.engine.qmm import (
    gate_up_proj,
    kv_proj,
    q_proj,
    qdot,
    qdot_group,
    qkv_proj,
)

__all__ = [
    "autotune",  # the submodule (measured autotuning lives there)
    "build_executable",
    "decode_weight_bytes",
    "choose_block",
    "gate_up_proj",
    "get_cache",
    "heuristic_block",
    "kv_proj",
    "q_proj",
    "qdot",
    "qdot_group",
    "qkv_proj",
    "supports_kernel_path",
    "TuneCache",
    "weight_bytes",
]
